"""System-level coherence checks: public API, configs, shape/skip rules."""
from repro.configs import ARCH_IDS, get_arch
from repro.configs.common import SHAPES


def test_public_api_imports():
    from repro.core import (SAConfig, SAResult, hybrid_minimize, nelder_mead,
                            sa_minimize)
    from repro.objectives import SUITE, get
    assert len(SUITE) == 41
    for api in (SAConfig, SAResult, hybrid_minimize, nelder_mead,
                sa_minimize, get):
        assert callable(api)


def test_all_ten_archs_registered():
    assert len(ARCH_IDS) == 10
    expected = {"gemma3-4b", "stablelm-1.6b", "granite-20b", "internlm2-20b",
                "falcon-mamba-7b", "jamba-v0.1-52b", "internvl2-2b",
                "whisper-base", "deepseek-v2-lite-16b", "kimi-k2-1t-a32b"}
    assert set(ARCH_IDS) == expected


def test_assigned_configs_match_table():
    """Exact assignment-table numbers (spot checks on every arch)."""
    rows = {
        "gemma3-4b": dict(d_model=2560, n_heads=8, n_kv_heads=4,
                          d_ff=10240, vocab_size=262144, n_layers=34),
        "stablelm-1.6b": dict(d_model=2048, n_heads=32, n_kv_heads=32,
                              d_ff=5632, vocab_size=100352, n_layers=24),
        "granite-20b": dict(d_model=6144, n_heads=48, n_kv_heads=1,
                            d_ff=24576, vocab_size=49152, n_layers=52),
        "internlm2-20b": dict(d_model=6144, n_heads=48, n_kv_heads=8,
                              d_ff=16384, vocab_size=92544, n_layers=48),
        "falcon-mamba-7b": dict(d_model=4096, vocab_size=65024, n_layers=64,
                                d_state=16),
        "jamba-v0.1-52b": dict(d_model=4096, n_heads=32, n_kv_heads=8,
                               d_ff=14336, vocab_size=65536, n_layers=32,
                               n_experts=16, top_k=2),
        "internvl2-2b": dict(d_model=2048, n_heads=16, n_kv_heads=8,
                             d_ff=8192, vocab_size=92553, n_layers=24),
        "whisper-base": dict(d_model=512, n_heads=8, d_ff=2048,
                             vocab_size=51865, n_layers=6, n_enc_layers=6),
        "deepseek-v2-lite-16b": dict(d_model=2048, n_heads=16,
                                     vocab_size=102400, n_layers=27,
                                     n_experts=64, top_k=6, kv_lora=512),
        "kimi-k2-1t-a32b": dict(d_model=7168, n_heads=64, n_kv_heads=8,
                                vocab_size=163840, n_layers=61,
                                n_experts=384, top_k=8),
    }
    for aid, want in rows.items():
        cfg = get_arch(aid).model
        for k, v in want.items():
            got = getattr(cfg, k)
            assert got == v, f"{aid}.{k}: {got} != {v}"


def test_param_counts_plausible():
    """Analytic param counts land near the family nameplate sizes."""
    # granite lands at ~28B here: the assignment table's d_ff=24576 with the
    # uniform SwiGLU substrate (3 mats) vs upstream's non-gated 2-mat MLP.
    approx = {"gemma3-4b": (3e9, 6e9), "stablelm-1.6b": (1.2e9, 2.2e9),
              "granite-20b": (15e9, 29e9), "internlm2-20b": (15e9, 25e9),
              "falcon-mamba-7b": (5e9, 9e9), "jamba-v0.1-52b": (40e9, 60e9),
              "internvl2-2b": (1.5e9, 3e9), "whisper-base": (4e7, 1.2e8),
              "deepseek-v2-lite-16b": (12e9, 20e9),
              "kimi-k2-1t-a32b": (0.8e12, 1.3e12)}
    for aid, (lo, hi) in approx.items():
        total, active = get_arch(aid).model.param_count()
        assert lo <= total <= hi, \
            f"{aid}: {total/1e9:.1f}B not in [{lo/1e9},{hi/1e9}]B"
        assert active <= total


def test_moe_active_counts():
    """MoE active << total (a32b: ~32B active of ~1T)."""
    total, active = get_arch("kimi-k2-1t-a32b").model.param_count()
    assert 20e9 <= active <= 45e9, f"active {active/1e9:.1f}B"
    total, active = get_arch("deepseek-v2-lite-16b").model.param_count()
    assert active < 0.3 * total


def test_shape_skip_rules():
    """DESIGN.md §5: long_500k only for sub-quadratic archs; decode for all
    (no encoder-only archs in this pool)."""
    long_ok = {aid for aid in ARCH_IDS
               if any(s == "long_500k" for s, _ in get_arch(aid).shapes())}
    assert long_ok == {"gemma3-4b", "falcon-mamba-7b", "jamba-v0.1-52b"}
    for aid in ARCH_IDS:
        names = [s for s, _ in get_arch(aid).shapes()]
        assert "train_4k" in names and "prefill_32k" in names
        assert "decode_32k" in names

    # 33 dry-run cells total (DESIGN.md §5)
    n_cells = sum(len(list(get_arch(a).shapes())) for a in ARCH_IDS)
    assert n_cells == 33


def test_shapes_table_is_assignment():
    assert SHAPES["train_4k"] == (4096, 256, "train")
    assert SHAPES["prefill_32k"] == (32768, 32, "prefill")
    assert SHAPES["decode_32k"] == (32768, 128, "decode")
    assert SHAPES["long_500k"] == (524288, 1, "decode")
