"""Elastic fleet: shard drain, watermark rebalancing, proactive degrade.

Tentpole guarantees (PR 5):

* **drain == uninterrupted run**: a shard drained at *every* temperature
  level of a resident ladder evacuates its jobs (migrate / shrink /
  swap) with champion trajectories bit-exact versus never having moved;
* **drain always completes and never loses work**: the draining shard
  retires once empty, every submitted request still reaches exactly one
  terminal status, and no slot leaks on any surviving shard;
* **resize composes drain/add**: the fleet grows and shrinks mid-stream
  with stable (never-reused) shard indices;
* **watermark rebalancing converges without thrash**: moves flow from
  shards above the high watermark to shards below the low one, never
  invert the load ordering, and stop once no shard is over watermark;
* **proactive degrade**: a running wide job shrinks (checkpoint ->
  restore at fewer slots, never below its floor) to seat a higher-
  priority arrival, and the shrunk trajectory is bit-exact versus a
  standalone replay of the same width schedule.

Everything runs on logical shards, so the whole file is tier-1; the CI
multi-device job re-runs it with 4 real XLA host devices.
"""

import dataclasses

import numpy as np
import pytest

from repro.service import (
    ArrivalProcess,
    EngineConfig,
    SARequest,
    SAServeEngine,
    SchedulerConfig,
    run_standalone,
)
from repro.service.scheduler import AdmissionScheduler, ShardView

CPS = 8


def _req(req_id, **kw):
    kw.setdefault("objective", "rastrigin")
    kw.setdefault("dim", 4)
    kw.setdefault("n_chains", CPS)
    kw.setdefault("T0", 50.0)
    kw.setdefault("T_min", 1.0)
    kw.setdefault("rho", 0.55)  # 7-level ladder
    kw.setdefault("N", 10)
    return SARequest(req_id=req_id, seed=100 + req_id, **kw)


def _cfg(n_slots=2, n_devices=2, **kw):
    return EngineConfig(
        n_slots=n_slots,
        chains_per_slot=CPS,
        n_devices=n_devices,
        use_pallas=False,
        **kw,
    )


def _sched_cfg(**kw):
    kw.setdefault("overload", "degrade")
    kw.setdefault("default_deadline", 50.0)
    return SchedulerConfig(**kw)


def _assert_bit_exact(res, solo):
    assert res.f_best == solo.f_best
    np.testing.assert_array_equal(res.x_best, solo.x_best)
    assert res.levels_run == solo.levels_run
    assert res.champion_history == solo.champion_history


def _assert_no_leaks(engine):
    for shard in engine.shards:
        assert shard.pool.n_free == shard.pool.n_slots
        assert np.all(shard.pool.owner == -1)
        assert not shard.rids.jobs


def _home_of(engine, req_id):
    jobs = {j.req.req_id: j for _, j in engine._iter_jobs()}
    return jobs[req_id].home_shard


# ------------------------------------------------------------------ drain
def test_drain_bit_exact_at_every_level():
    """Acceptance criterion: drain the victim's home shard at every
    temperature level of its ladder; the evacuated trajectory (best
    value, best x, per-level champions) is bit-exact versus the
    uninterrupted single-device run."""
    cfg = _cfg(n_slots=1, n_devices=2, migration_budget=2)
    victim = _req(0)
    solo = run_standalone(victim, cfg)
    assert solo.levels_run == victim.n_levels > 2
    for level in range(1, victim.n_levels):
        engine = SAServeEngine(cfg)
        engine.submit(victim)
        for _ in range(level):
            engine.tick()
        home = _home_of(engine, 0)
        engine.drain(home)
        res = engine.run(max_ticks=200)[0]
        assert res.migrated_ticks == [level]
        assert res.home_shard != home
        _assert_bit_exact(res, solo)
        assert engine.retired_shards == [(home, level)]
        _assert_no_leaks(engine)


def test_drain_retires_shard_and_refuses_placements():
    """A draining shard takes no new placements (engine.migrate refuses
    it too) and is removed from the fleet once empty; draining an
    already-empty shard retires it immediately."""
    engine = SAServeEngine(_cfg(n_slots=3, n_devices=2, migration_budget=2))
    engine.submit(_req(0, rho=0.9))  # long ladder -> shard 0
    engine.tick()
    engine.submit(_req(1, rho=0.9))  # least-loaded -> shard 1
    engine.tick()
    engine.drain(1)
    assert engine.shards[1].draining
    assert not engine.migrate(0, 1)  # draining target refused
    engine.submit(_req(2, rho=0.5, T0=8.0))
    engine.tick()  # admits req2 AND evacuates req1, both onto shard 0
    assert _home_of(engine, 2) == 0, "new work placed on a draining shard"
    assert [i for i, _ in engine.retired_shards] == [1]
    assert engine.stats()["devices"] == 1
    results = engine.run(max_ticks=300)
    assert all(r.completed for r in results)
    # Draining an empty shard retires it on the spot.
    idle = SAServeEngine(_cfg(n_slots=1, n_devices=2))
    idle.drain(1)
    assert [s.index for s in idle.shards] == [0]
    assert idle.retired_shards == [(1, 0)]


def test_drain_is_idempotent_and_guards_last_shard():
    engine = SAServeEngine(_cfg(n_slots=1, n_devices=2, migration_budget=0))
    engine.submit(_req(0, rho=0.9))
    engine.submit(_req(1, rho=0.9))
    engine.tick()  # one job per shard; budget 0 freezes evacuation
    engine.drain(1)
    engine.drain(1)  # idempotent while still draining
    assert sum(s.draining for s in engine.shards) == 1
    with pytest.raises(ValueError):
        engine.drain(0)  # would leave no live shard
    with pytest.raises(ValueError):
        engine.drain(7)  # no such shard
    # A retired shard's index is gone for good.
    idle = SAServeEngine(_cfg(n_slots=1, n_devices=3))
    idle.drain(2)
    with pytest.raises(ValueError):
        idle.drain(2)


def test_drain_full_survivors_swaps_to_queue_and_resumes():
    """When no survivor has room, drained jobs checkpoint to the queue
    (bounded per tick) and resume bit-exactly once capacity frees."""
    cfg = _cfg(n_slots=1, n_devices=2, migration_budget=1)
    blocker, victim = _req(0, T0=8.0, rho=0.5), _req(1)
    engine = SAServeEngine(cfg)
    engine.submit(blocker)
    engine.submit(victim)
    engine.tick()  # one job per shard, both full
    engine.drain(_home_of(engine, 1))
    results = {r.req_id: r for r in engine.run(max_ticks=300)}
    assert results[1].n_preemptions >= 1, "drain had to swap, not migrate"
    _assert_bit_exact(results[1], run_standalone(victim, cfg))
    _assert_bit_exact(results[0], run_standalone(blocker, cfg))
    assert len(engine.retired_shards) == 1
    _assert_no_leaks(engine)


def test_drain_evacuates_in_priority_order():
    """Highest-effective-priority jobs leave the doomed shard first."""
    engine = SAServeEngine(_cfg(n_slots=3, n_devices=2, migration_budget=1))
    engine.submit(_req(0, priority=0, rho=0.9))
    engine.tick()  # low-prio -> shard 0
    engine.submit(_req(1, priority=9, rho=0.9))
    engine.tick()  # high-prio -> shard 1 (least loaded)
    engine.drain(_home_of(engine, 1))
    engine.submit(_req(2, priority=9, rho=0.9))  # rides along on survivor
    engine.tick()
    moved = {j.req.req_id: j.migrated_ticks for _, j in engine._iter_jobs()}
    assert moved[1], "high-priority job did not evacuate first"


def test_drain_shrinks_wide_job_into_survivor():
    """A degrade-class job too wide for any survivor is shrunk into the
    roomiest one (never below its floor) and stays bit-exact versus a
    standalone replay of the same width schedule."""
    cfg = _cfg(n_slots=3, n_devices=2, migration_budget=2)
    wide = _req(0, n_chains=3 * CPS, min_chains=CPS, on_overload="degrade", rho=0.8)
    narrow = _req(1, T0=8.0, rho=0.9, n_chains=2 * CPS)
    engine = SAServeEngine(cfg)
    engine.submit(wide)
    engine.tick()  # wide -> shard 0, full width (3 slots)
    engine.submit(narrow)
    engine.tick()  # narrow -> shard 1 (2 of 3 slots)
    engine.drain(0)
    results = {r.req_id: r for r in engine.run(max_ticks=300)}
    res = results[0]
    assert res.n_shrinks == 1
    level, frm, to = res.shrink_events[0]
    assert (frm, to) == (3 * CPS, CPS)  # only 1 slot free on the survivor
    assert res.granted_chains == CPS
    solo = run_standalone(wide, cfg, shrink_schedule=[(level, to)])
    _assert_bit_exact(res, solo)
    _assert_no_leaks(engine)


# ----------------------------------------------------------------- resize
def test_resize_grows_and_shrinks_mid_stream():
    """resize() composes drain/add: capacity grows with fresh shard
    indices, shrinks by draining the emptiest shards, and every request
    stays bit-exact throughout."""
    cfg = _cfg(n_slots=1, n_devices=2, migration_budget=2)
    engine = SAServeEngine(cfg)
    reqs = [_req(i, rho=0.8) for i in range(6)]
    for r in reqs:
        engine.submit(r)
    engine.tick()
    assert engine.n_active == 2
    engine.resize(4)
    assert sorted(s.index for s in engine.live_shards) == [0, 1, 2, 3]
    engine.tick()
    assert engine.n_active == 4, "added capacity not used"
    engine.resize(2)
    results = {r.req_id: r for r in engine.run(max_ticks=500)}
    assert len(engine.shards) == 2
    assert len(engine.retired_shards) == 2
    for r in reqs:
        _assert_bit_exact(results[r.req_id], run_standalone(r, cfg))
    _assert_no_leaks(engine)


def test_resize_up_cancels_inflight_drain():
    """Growing while a drain is evacuating un-drains the shard instead of
    paying retire + fresh-shard churn."""
    engine = SAServeEngine(_cfg(n_slots=1, n_devices=2))
    engine.submit(_req(0, rho=0.9))
    engine.submit(_req(1, rho=0.9))
    engine.tick()
    engine.drain(1)
    assert engine.shards[1].draining
    engine.resize(2)
    assert not engine.shards[1].draining
    assert len(engine.shards) == 2  # no shard added
    results = engine.run(max_ticks=300)
    assert all(r.completed for r in results)
    assert engine.retired_shards == []


def test_resize_never_reuses_indices():
    engine = SAServeEngine(_cfg(n_slots=1, n_devices=3))
    engine.resize(1)
    assert [s.index for s in engine.shards] == [0]
    engine.resize(3)
    assert [s.index for s in engine.shards] == [0, 3, 4]
    with pytest.raises(ValueError):
        engine.resize(0)


def test_scheduled_ops_fire_on_their_tick_and_survive_idle_jumps():
    """schedule_op lands drain/resize on the exact tick even when the
    open-loop driver fast-forwards through idle time."""
    engine = SAServeEngine(_cfg(n_slots=1, n_devices=3))
    seen = []
    engine.schedule_op(5, lambda: seen.append(engine.tick_count))
    engine.schedule_op(5, lambda: engine.resize(2))
    # Lone arrival far in the future: the driver jumps over tick 5 only
    # after the op has fired there.
    arrivals = ArrivalProcess.trace([_req(0, T0=8.0, rho=0.5)], [12.0])
    results = engine.run_stream(arrivals, max_ticks=100)
    assert seen == [5]
    assert len(engine.shards) == 2
    assert results[0].completed and results[0].start_tick >= 12


# ---------------------------------------------------- watermark rebalancing
def test_watermark_rebalance_moves_work_off_hot_shard():
    """A shard above the high watermark sheds narrow jobs onto a shard
    below the low watermark; moves stop at balance (no thrash) and every
    moved trajectory stays bit-exact."""
    sched = SchedulerConfig(high_watermark=0.7, low_watermark=0.5)
    cfg = _cfg(n_slots=4, n_devices=2, migration_budget=2, scheduler=sched)
    engine = SAServeEngine(cfg)
    reqs = [_req(i, rho=0.9) for i in range(4)]
    for r in reqs:
        engine.submit(r)
    engine.tick()
    for _, j in list(engine._iter_jobs()):  # force the hot shard
        if j.home_shard == 1:
            engine.migrate(j.req.req_id, 0)
    forced = engine.migrations
    assert [s.pool.n_active for s in engine.shards] == [4, 0]
    engine.tick()
    assert engine.migrations - forced == 2, "rebalancer did not fire"
    assert [s.pool.n_active for s in engine.shards] == [2, 2]
    engine.tick()
    assert engine.migrations - forced == 2, "rebalancer thrashed at balance"
    results = {r.req_id: r for r in engine.run(max_ticks=500)}
    for r in reqs:
        _assert_bit_exact(results[r.req_id], run_standalone(r, cfg))


def test_watermarks_disabled_by_default():
    engine = SAServeEngine(_cfg(n_slots=4, n_devices=2, migration_budget=2))
    for i in range(4):
        engine.submit(_req(i, rho=0.9))
    engine.tick()
    for _, j in list(engine._iter_jobs()):
        if j.home_shard == 1:
            engine.migrate(j.req.req_id, 0)
    forced = engine.migrations
    engine.tick()
    assert engine.migrations == forced, "rebalance fired at the defaults"


def test_plan_rebalance_never_inverts_load_ordering():
    """Deterministic mini-fuzz over random shard snapshots: every planned
    move respects capacity and the donor stays at least as loaded as the
    recipient — the structural no-thrash guarantee."""
    from repro.service.slots import ActiveJob

    rng = np.random.default_rng(7)
    sch = AdmissionScheduler(SchedulerConfig(high_watermark=0.6, low_watermark=0.4))
    for _trial in range(200):
        n_shards = int(rng.integers(2, 5))
        n_slots = int(rng.integers(2, 6))
        views, rid = [], 0
        for i in range(n_shards):
            used, jobs = 0, []
            while used < n_slots and rng.random() < 0.7:
                width = int(rng.integers(1, n_slots - used + 1))
                req = _req(1000 + rid, n_chains=width * CPS)
                slots = list(range(used, used + width))
                jobs.append(ActiveJob(req=req, rid=rid, slots=slots))
                rid += 1
                used += width
            shapes = frozenset((j.req.dim, j.req.N) for j in jobs)
            view = ShardView(
                index=i,
                free_slots=n_slots - used,
                active=tuple(jobs),
                shapes=shapes,
            )
            views.append(view)
        budget = int(rng.integers(0, 4))
        moves = sch.plan_rebalance(views, tick=10, budget=budget)
        assert len(moves) <= budget
        free = {v.index: v.free_slots for v in views}
        used = {v.index: v.used_slots for v in views}
        width_of = {(v.index, j.rid): len(j.slots) for v in views for j in v.active}
        for mrid, src, dst in moves:
            w = width_of.pop((src, mrid))
            assert free[dst] >= w, "recipient cannot seat the move"
            assert used[src] - w >= used[dst] + w, "load ordering inverted"
            free[src] += w
            free[dst] -= w
            used[src] -= w
            used[dst] += w


# -------------------------------------------------------- proactive degrade
def test_proactive_degrade_bit_exact_at_every_level():
    """Acceptance criterion: shrink a running wide job at every ladder
    level; each trajectory equals the standalone replay of the same
    (level, width) schedule."""
    cfg = _cfg(n_slots=3, n_devices=1)
    wide = _req(0, n_chains=3 * CPS, min_chains=CPS)
    for level in range(1, wide.n_levels):
        engine = SAServeEngine(cfg)
        engine.submit(wide)
        for _ in range(level):
            engine.tick()
        assert engine.degrade_active(0, CPS)
        res = engine.run(max_ticks=200)[0]
        assert res.shrink_events == [(level, 3 * CPS, CPS)]
        assert res.shrunk_ticks == [level]
        solo = run_standalone(wide, cfg, shrink_schedule=[(level, CPS)])
        _assert_bit_exact(res, solo)
        assert res.granted_chains == CPS
        assert res.admitted_chains == 3 * CPS


def test_scheduler_shrinks_running_job_to_seat_urgent_arrival():
    """With proactive_degrade on, a full pool shrinks a lower-priority
    degrade-class job instead of queueing the urgent arrival."""
    sched = _sched_cfg(proactive_degrade=True, shrink_budget=2)
    cfg = _cfg(n_slots=3, n_devices=1, scheduler=sched)
    wide = _req(0, n_chains=3 * CPS, min_chains=CPS, rho=0.9, priority=0)
    urgent = _req(1, priority=5)
    engine = SAServeEngine(cfg)
    engine.submit(wide)
    engine.tick()
    engine.tick()
    engine.submit(urgent)
    engine.tick()
    widths = {j.req.req_id: len(j.slots) for _, j in engine._iter_jobs()}
    assert widths == {0: 2, 1: 1}, "wide job not shrunk / urgent not seated"
    assert engine.shrinks == 1
    results = {r.req_id: r for r in engine.run(max_ticks=500)}
    ev = results[0].shrink_events
    assert [(f, t) for _, f, t in ev] == [(3 * CPS, 2 * CPS)]
    sched = [(level, to) for level, _, to in ev]
    _assert_bit_exact(results[0], run_standalone(wide, cfg, shrink_schedule=sched))
    _assert_bit_exact(results[1], run_standalone(urgent, cfg))


def test_proactive_degrade_respects_floor_and_priority():
    """Never shrinks below min_chains, and never shrinks for an arrival
    that does not outrank the running job."""
    cfg = _cfg(
        n_slots=2,
        n_devices=1,
        scheduler=_sched_cfg(proactive_degrade=True, aging=0.0),
    )
    # Floor: the wide job may not shrink below 2 slots, so nothing frees.
    engine = SAServeEngine(cfg)
    engine.submit(_req(0, n_chains=2 * CPS, min_chains=2 * CPS, rho=0.9))
    engine.tick()
    engine.submit(_req(1, priority=9))
    engine.tick()
    assert engine.shrinks == 0
    assert engine.n_active == 1
    # Priority: an equal-priority arrival must not trigger a shrink.
    engine2 = SAServeEngine(cfg)
    engine2.submit(_req(0, n_chains=2 * CPS, min_chains=CPS, rho=0.9))
    engine2.tick()
    engine2.submit(_req(1, priority=0))
    engine2.tick()
    assert engine2.shrinks == 0


def test_degrade_active_refuses_bad_targets():
    engine = SAServeEngine(_cfg(n_slots=2, n_devices=1))
    assert not engine.degrade_active(0, CPS)  # not submitted
    engine.submit(_req(0, n_chains=2 * CPS))
    engine.tick()
    assert not engine.degrade_active(0, 2 * CPS)  # not a reduction
    assert not engine.degrade_active(0, 3 * CPS)  # widening unsupported
    assert engine.degrade_active(0, CPS)
    assert engine.shrinks == 1


# ----------------------------------------------- composition / accounting
def test_drain_under_open_loop_stream_loses_nothing():
    """End-to-end acceptance shape: a seeded Poisson stream over 4 shards
    with one shard drained mid-flight — every request reaches exactly one
    terminal status, nothing is lost or duplicated across retirement,
    and the run is deterministic."""

    def one_run():
        engine = SAServeEngine(_cfg(n_slots=2, n_devices=4, migration_budget=2))
        engine.schedule_op(6, lambda: engine.drain(3))
        reqs = [_req(i, rho=0.8) for i in range(12)]
        arrivals = ArrivalProcess.poisson(reqs, rate=1.5, seed=11)
        engine.run_stream(arrivals, max_ticks=800)
        return engine

    engine = one_run()
    ids = sorted(r.req_id for r in engine.results)
    assert ids == list(range(12)), "lost or duplicated requests"
    assert all(r.completed for r in engine.results)
    assert [i for i, _ in engine.retired_shards] == [3]
    _assert_no_leaks(engine)
    a = [(r.req_id, r.f_best, r.finish_tick) for r in engine.results]
    b = [(r.req_id, r.f_best, r.finish_tick) for r in one_run().results]
    assert sorted(a) == sorted(b), "drain broke tick-clock determinism"


def test_random_drain_resize_fuzz_no_leaks_one_terminal():
    """Deterministic mini-fuzz (seeded numpy, tier-1): random arrivals x
    random drain/resize/degrade points -> no slot leaks, exactly one
    terminal status per request, fleet invariants hold throughout."""
    rng = np.random.default_rng(3)
    for trial in range(6):
        sched = _sched_cfg(
            default_deadline=40.0,
            proactive_degrade=bool(rng.integers(0, 2)),
            high_watermark=0.75,
            low_watermark=0.25,
        )
        cfg = _cfg(
            n_slots=2,
            n_devices=int(rng.integers(2, 4)),
            migration_budget=int(rng.integers(1, 3)),
            scheduler=sched,
        )
        engine = SAServeEngine(cfg)
        n_reqs = int(rng.integers(3, 8))
        reqs = []
        for i in range(n_reqs):
            width = int(rng.integers(1, 3))
            prio = int(rng.integers(0, 3))
            req = _req(i, rho=0.7, n_chains=width * CPS, min_chains=CPS, priority=prio)
            reqs.append(req)
        times = [float(rng.uniform(0, 10)) for _ in reqs]
        arrivals = ArrivalProcess.trace(reqs, times)
        guard = 0
        while not (engine.done and arrivals.exhausted):
            guard += 1
            assert guard < 500, "engine failed to drain (livelock?)"
            for t, r in arrivals.due(engine.tick_count):
                engine.submit(r, t)
            roll = rng.random()
            live = engine.live_shards
            if roll < 0.15 and len(live) > 1:
                engine.drain(live[int(rng.integers(0, len(live)))].index)
            elif roll < 0.3:
                engine.resize(int(rng.integers(1, 5)))
            elif roll < 0.4:
                active = [j.req.req_id for _, j in engine._iter_jobs()]
                if active:
                    engine.degrade_active(int(rng.choice(active)), CPS)
            engine.tick()
            resident = [j.req.req_id for _, j in engine._iter_jobs()]
            assert len(resident) == len(set(resident)), "double placement"
        _assert_no_leaks(engine)
        ids = sorted(r.req_id for r in engine.results)
        assert ids == list(range(n_reqs)), "lost/duplicated terminal"
        retired = [i for i, _ in engine.retired_shards]
        assert len(retired) == len(set(retired)), "index reuse"
        for res in engine.results:
            if not res.completed:
                continue
            req = reqs[res.req_id]
            if res.admitted_chains < req.n_chains:
                req = dataclasses.replace(req, n_chains=res.admitted_chains)
            sched_replay = [(lvl, to) for lvl, _, to in res.shrink_events]
            solo = run_standalone(req, cfg, shrink_schedule=sched_replay)
            assert res.f_best == solo.f_best, (trial, res.req_id)
            assert res.champion_history == solo.champion_history


def test_occupancy_accounting_with_elastic_fleet():
    """slot_ticks tracks the actual fleet size, so occupancy stays in
    [0, 1] across drain and resize (a fixed ticks x slots denominator
    would over- or under-count)."""
    engine = SAServeEngine(_cfg(n_slots=1, n_devices=3, migration_budget=2))
    for i in range(3):
        engine.submit(_req(i, rho=0.8))
    engine.tick()
    engine.drain(2)
    engine.run(max_ticks=300)
    stats = engine.stats()
    assert 0.0 < stats["occupancy"] <= 1.0
    assert all(0.0 <= u <= 1.0 for u in stats["shard_occupancy"])
    assert stats["shards_retired"] == 1 and stats["draining"] == 0
    d = engine.results[0].to_dict()
    assert {"shrunk_ticks", "shrink_events", "n_shrinks"} <= set(d)
